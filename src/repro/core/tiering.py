"""Hybrid-storage log-structured store (paper §V: DRAM + SSD spill).

Writes append to an in-memory segment log (DRAM tier); when DRAM capacity is
exceeded, *whole segments* spill to an SSD-tier file with a single sequential
append — log-structuring is exactly what made bbIORSSD (198.8 MB/s) match
SSDSeq (206 MB/s) in the paper's Fig 6 while direct semi-random writes got
166.7 MB/s. An index maps key -> (tier, segment/file, offset, length, gen).

Drain-engine support (ISSUE 3):
  - every put stamps a monotonically increasing write generation, so the
    drainer can tell "same key, rewritten since the drain epoch snapshot"
    from "same bytes the epoch made durable" and never evict fresh data;
  - ``evict()`` tombstones a durably-flushed key (tier "pfs"): reads miss,
    the residency is remembered, and the bytes are reclaimed by compact();
  - ``compact()`` reclaims BOTH tiers — dead DRAM segments are dropped and
    the SSD log is rewritten keeping only live entries;
  - ``occupancy()``/``cold_keys()`` feed the watermark policy: occupancy is
    used bytes over DRAM+SSD capacity, cold keys are whole sealed segments
    in age order (SSD first — it spilled earliest — then DRAM by segment id).

Stage-in support (ISSUE 4): a put may be marked ``clean`` — the bytes were
re-ingested from a durable PFS copy (staging.py), so eviction loses nothing
and needs no flush epoch. ``cold_keys(clean=True)`` lists the free-eviction
candidates; a plain rewrite of the key clears the flag.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from . import locktrack


@dataclass
class _Loc:
    tier: str          # "dram" | "ssd" | "pfs" (evicted tombstone)
    segment: int       # dram segment id or ssd file offset base id
    offset: int
    length: int
    gen: int = 0       # write generation (monotonic per store)
    clean: bool = False  # a durable PFS copy exists (stage-in re-ingest):
    #                      evictable for free, without a flush epoch


class LogStore:
    SEGMENT_BYTES = 4 << 20

    def __init__(self, dram_capacity: int, ssd_dir: Optional[str] = None,
                 name: str = "srv", *,
                 ssd_capacity: Optional[int] = None,
                 segment_bytes: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self.dram_capacity = dram_capacity
        self.ssd_dir = ssd_dir
        self.name = name
        self.segment_bytes = segment_bytes or self.SEGMENT_BYTES
        self._segments: Dict[int, bytearray] = {}
        self._open_seg = 0
        self._segments[0] = bytearray()
        self._index: Dict[str, _Loc] = {}
        self._dram_bytes = 0
        self._ssd_bytes = 0
        self._next_seg = 1
        self._gen = 0
        self._seg_touched: Dict[int, float] = {0: clock()}
        self._lock = locktrack.rlock("LogStore._lock")
        self._ssd_path = None
        if ssd_dir:
            os.makedirs(ssd_dir, exist_ok=True)
            self._ssd_path = os.path.join(ssd_dir, f"{name}.log")
            open(self._ssd_path, "wb").close()
        if ssd_capacity is None:
            # soft budget for the watermark policy, not a hard write limit:
            # the log absorbs past it, the drainer is what pulls it back down
            ssd_capacity = 4 * dram_capacity if self._ssd_path else 0
        self.ssd_capacity = ssd_capacity

    # ------------------------------------------------------------------ info
    @property
    def dram_used(self) -> int:
        with self._lock:
            return self._dram_bytes

    @property
    def ssd_used(self) -> int:
        with self._lock:
            return self._ssd_bytes

    def dram_free(self) -> int:
        with self._lock:
            return max(0, self.dram_capacity - self._dram_bytes)

    def occupancy(self) -> Dict[str, float]:
        """Watermark input: used bytes over total (DRAM + SSD) capacity.
        The fraction can exceed 1.0 — the SSD log is soft-capped and keeps
        absorbing; that is exactly the pressure signal the drainer acts on."""
        with self._lock:
            cap = self.dram_capacity + self.ssd_capacity
            used = self._dram_bytes + self._ssd_bytes
            return {"dram_used": self._dram_bytes,
                    "dram_capacity": self.dram_capacity,
                    "ssd_used": self._ssd_bytes,
                    "ssd_capacity": self.ssd_capacity,
                    "used": used, "capacity": cap,
                    "fraction": used / cap if cap else 0.0}

    def keys(self) -> List[str]:
        with self._lock:
            return [k for k, loc in self._index.items() if loc.tier != "pfs"]

    def __contains__(self, key: str) -> bool:
        with self._lock:
            loc = self._index.get(key)
            return loc is not None and loc.tier != "pfs"

    def tier_of(self, key: str) -> Optional[str]:
        """Residency of a key: "dram" | "ssd" | "pfs" (evicted) | None."""
        with self._lock:
            loc = self._index.get(key)
            return loc.tier if loc is not None else None

    def gen_of(self, key: str) -> Optional[int]:
        with self._lock:
            loc = self._index.get(key)
            return loc.gen if loc is not None else None

    def was_evicted(self, key: str) -> bool:
        return self.tier_of(key) == "pfs"

    def is_clean(self, key: str) -> bool:
        """True when the key's bytes were staged in from a durable PFS copy
        (and not rewritten since): evicting them loses nothing."""
        with self._lock:
            loc = self._index.get(key)
            return loc is not None and loc.tier != "pfs" and loc.clean

    # ----------------------------------------------------------------- write
    def put(self, key: str, value: bytes, *, clean: bool = False) -> str:
        """Append to the DRAM log; spill oldest segments to SSD if needed.
        Returns the tier the value landed in. ``clean`` marks the bytes as
        having a durable PFS copy already (stage-in re-ingest) — a plain
        rewrite of the same key clears the flag."""
        with self._lock:
            if key in self._index:
                self.delete(key)
            self._gen += 1
            seg = self._segments[self._open_seg]
            loc = _Loc("dram", self._open_seg, len(seg), len(value),
                       self._gen, clean)
            seg += value
            self._index[key] = loc
            self._dram_bytes += len(value)
            self._seg_touched[self._open_seg] = self._clock()
            if len(seg) >= self.segment_bytes:
                self._roll_segment()
            spilled = self._maybe_spill()
            return "ssd" if spilled and self._index[key].tier == "ssd" \
                else "dram"

    def _roll_segment(self):
        self._segments[self._next_seg] = bytearray()
        self._open_seg = self._next_seg
        self._seg_touched[self._open_seg] = self._clock()
        self._next_seg += 1

    def _maybe_spill(self) -> bool:
        """Spill closed segments (oldest first) while over DRAM capacity."""
        if self._dram_bytes <= self.dram_capacity or not self._ssd_path:
            return False
        # if the open segment alone holds the overflow, roll it so it can
        # spill too (log-structured: only sealed segments move)
        if len(self._segments) == 1 and self._segments[self._open_seg]:
            self._roll_segment()
        spilled = False
        with open(self._ssd_path, "ab") as f:
            for seg_id in sorted(self._segments):
                if self._dram_bytes <= self.dram_capacity:
                    break
                if seg_id == self._open_seg:
                    continue
                data = bytes(self._segments.pop(seg_id))
                self._seg_touched.pop(seg_id, None)
                base = f.tell()
                f.write(data)                    # sequential append
                for k, loc in self._index.items():
                    if loc.tier == "dram" and loc.segment == seg_id:
                        self._index[k] = _Loc("ssd", 0, base + loc.offset,
                                              loc.length, loc.gen, loc.clean)
                self._dram_bytes -= len(data)
                self._ssd_bytes += len(data)
                spilled = True
        return spilled

    # ------------------------------------------------------------------ read
    def get(self, key: str) -> Optional[bytes]:
        with self._lock:
            loc = self._index.get(key)
            if loc is None or loc.tier == "pfs":
                return None
            if loc.tier == "dram":
                seg = self._segments[loc.segment]
                return bytes(seg[loc.offset:loc.offset + loc.length])
            with open(self._ssd_path, "rb") as f:
                f.seek(loc.offset)
                return f.read(loc.length)

    def delete(self, key: str):
        """Log-structured delete: drop the index entry (tombstones too);
        dead bytes are reclaimed by compact()."""
        with self._lock:
            self._index.pop(key, None)

    def evict(self, key: str) -> int:
        """Tombstone a durably-flushed key: the index remembers it moved to
        the "pfs" tier (reads miss, residency is reportable), and the dead
        bytes are reclaimed by compact(). Idempotent — evicting a missing or
        already-evicted key frees 0, so a replayed drain_evict can never
        double-free accounting."""
        with self._lock:
            loc = self._index.get(key)
            if loc is None or loc.tier == "pfs":
                return 0
            self._index[key] = _Loc("pfs", -1, 0, loc.length, loc.gen)
            return loc.length

    def cold_keys(self, min_idle_s: float = 0.0,
                  now: Optional[float] = None, *,
                  clean: Optional[bool] = None) -> List[Tuple[str, int]]:
        """Drain candidates in age order: SSD-resident keys first (they
        spilled earliest, i.e. are the coldest), then keys of sealed DRAM
        segments oldest-segment-first. The open segment never drains, and a
        DRAM segment appended to within ``min_idle_s`` is considered warm.
        ``clean`` filters by the clean flag (True: only staged/re-ingested
        keys — the free-eviction candidates; False: only dirty keys — the
        ones that need a drain epoch; None: both). Returns [(key, length)]."""
        now = self._clock() if now is None else now
        with self._lock:
            ssd = sorted((loc.offset, k, loc.length)
                         for k, loc in self._index.items()
                         if loc.tier == "ssd"
                         and (clean is None or loc.clean == clean))
            dram = sorted(
                (loc.segment, loc.offset, k, loc.length)
                for k, loc in self._index.items()
                if loc.tier == "dram" and loc.segment != self._open_seg
                and (clean is None or loc.clean == clean)
                and now - self._seg_touched.get(loc.segment, 0.0)
                >= min_idle_s)
            return [(k, ln) for _, k, ln in ssd] \
                + [(k, ln) for _, _, k, ln in dram]

    def items_bytes(self) -> Dict[str, int]:
        with self._lock:
            return {k: loc.length for k, loc in self._index.items()
                    if loc.tier != "pfs"}

    def compact(self):
        """Reclaim dead bytes on BOTH tiers: drop fully-dead DRAM segments,
        and rewrite the SSD log keeping only live entries (one sequential
        copy, then an atomic replace) so deleted/evicted SSD bytes are
        actually returned — without this the drain engine would tombstone
        forever while the SSD file only ever grew."""
        with self._lock:
            live = {loc.segment for loc in self._index.values()
                    if loc.tier == "dram"}
            for seg_id in list(self._segments):
                if seg_id != self._open_seg and seg_id not in live:
                    self._dram_bytes -= len(self._segments[seg_id])
                    del self._segments[seg_id]
                    self._seg_touched.pop(seg_id, None)
            if not self._ssd_path:
                return
            ssd = sorted((loc.offset, k) for k, loc in self._index.items()
                         if loc.tier == "ssd")
            live_bytes = sum(self._index[k].length for _, k in ssd)
            if live_bytes >= self._ssd_bytes:
                return                        # nothing dead in the SSD log
            tmp = self._ssd_path + ".compact"
            with open(self._ssd_path, "rb") as src, open(tmp, "wb") as dst:
                for _, k in ssd:
                    loc = self._index[k]
                    src.seek(loc.offset)
                    data = src.read(loc.length)
                    self._index[k] = _Loc("ssd", 0, dst.tell(), loc.length,
                                          loc.gen, loc.clean)
                    dst.write(data)           # sequential rewrite
            os.replace(tmp, self._ssd_path)
            self._ssd_bytes = live_bytes
