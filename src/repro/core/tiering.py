"""Hybrid-storage log-structured store (paper §V: DRAM + SSD spill).

Writes append to an in-memory segment log (DRAM tier); when DRAM capacity is
exceeded, *whole segments* spill to an SSD-tier file with a single sequential
append — log-structuring is exactly what made bbIORSSD (198.8 MB/s) match
SSDSeq (206 MB/s) in the paper's Fig 6 while direct semi-random writes got
166.7 MB/s. An index maps key -> (tier, segment/file, offset, length, gen).

Drain-engine support (ISSUE 3):
  - every put stamps a monotonically increasing write generation, so the
    drainer can tell "same key, rewritten since the drain epoch snapshot"
    from "same bytes the epoch made durable" and never evict fresh data;
  - ``evict()`` tombstones a durably-flushed key (tier "pfs"): reads miss,
    the residency is remembered, and the bytes are reclaimed by compact();
  - ``compact()`` reclaims BOTH tiers — dead DRAM segments are dropped and
    the SSD log is rewritten keeping only live entries;
  - ``occupancy()``/``cold_keys()`` feed the watermark policy: occupancy is
    used bytes over DRAM+SSD capacity, cold keys are whole sealed segments
    in age order (SSD first — it spilled earliest — then DRAM by segment id).

Stage-in support (ISSUE 4): a put may be marked ``clean`` — the bytes were
re-ingested from a durable PFS copy (staging.py), so eviction loses nothing
and needs no flush epoch. ``cold_keys(clean=True)`` lists the free-eviction
candidates; a plain rewrite of the key clears the flag.

Crash recovery (ISSUE 8): the SSD log is self-describing. Every spill writes
one record per key — a fixed header (magic ``BBR1``, flags carrying the
clean/tombstone bits, write generation, key length, payload length) plus a
CRC32 over header+key+payload — and ``compact()`` preserves the format.
``delete()``/``evict()`` of an SSD-resident key append a tombstone record so
replay converges. On construction over an existing non-empty log the store
*recovers* instead of truncating: records are scanned last-gen-wins, a torn
tail is truncated at the first bad header/CRC, and the index, byte
accounting and generation counter are rebuilt; ``recovered_keys`` exposes
what came back so the server can rebuild its chunk manifests. Durability
discipline: spilled records are fsynced *before* the index publishes them as
tier "ssd", and compact fsyncs its tmp file before the atomic replace (the
old log stays valid until then, so a crash at any point replays cleanly).
"""
from __future__ import annotations

import os
import struct
import time
import zlib
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from . import locktrack, telemetry

# SSD log record: header | key bytes | payload bytes. The CRC is computed
# over the header (with the crc field zeroed) + key + payload, so a torn or
# bit-flipped record is detected and recovery truncates the tail there.
_REC_MAGIC = b"BBR1"
_REC_HDR = struct.Struct(">4sBQHII")  # magic, flags, gen, key_len, len, crc
_REC_CLEAN = 0x01   # payload has a durable PFS copy (stage-in re-ingest)
_REC_TOMB = 0x02    # tombstone: the key was deleted/evicted at this gen


@dataclass
class _Loc:
    tier: str          # "dram" | "ssd" | "pfs" (evicted tombstone)
    segment: int       # dram segment id or ssd file offset base id
    offset: int
    length: int
    gen: int = 0       # write generation (monotonic per store)
    clean: bool = False  # a durable PFS copy exists (stage-in re-ingest):
    #                      evictable for free, without a flush epoch


class LogStore:
    SEGMENT_BYTES = 4 << 20

    def __init__(self, dram_capacity: int, ssd_dir: Optional[str] = None,
                 name: str = "srv", *,
                 ssd_capacity: Optional[int] = None,
                 segment_bytes: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self.dram_capacity = dram_capacity
        self.ssd_dir = ssd_dir
        self.name = name
        self.segment_bytes = segment_bytes or self.SEGMENT_BYTES
        self._segments: Dict[int, bytearray] = {}
        self._open_seg = 0
        self._segments[0] = bytearray()
        self._index: Dict[str, _Loc] = {}
        self._dram_bytes = 0
        self._ssd_bytes = 0
        self._next_seg = 1
        self._gen = 0
        self._seg_touched: Dict[int, float] = {0: clock()}
        self._lock = locktrack.rlock("LogStore._lock")
        self._ssd_path = None
        self._read_fh = None     # cached SSD read handle (ISSUE 8 satellite)
        self._append_fh = None   # cached SSD append handle
        self._unsynced = False   # tombstones flushed but not yet fsynced
        self.recovered_keys: List[str] = []
        # telemetry (ISSUE 9): spill/compact/fsync latencies + CRC-failure
        # counter; bound before recover() runs so the recovery scan can
        # count bad records. No-op singletons when telemetry is disabled.
        self._m_spill = telemetry.histogram("store.spill_s")
        self._m_fsync = telemetry.histogram("store.fsync_s")
        self._m_compact = telemetry.histogram("store.compact_s")
        self._m_crc = telemetry.counter("store.crc_failures")
        if ssd_dir:
            os.makedirs(ssd_dir, exist_ok=True)
            self._ssd_path = os.path.join(ssd_dir, f"{name}.log")
            if os.path.exists(self._ssd_path) \
                    and os.path.getsize(self._ssd_path) > 0:
                self.recover()
            else:
                open(self._ssd_path, "wb").close()
        if ssd_capacity is None:
            # soft budget for the watermark policy, not a hard write limit:
            # the log absorbs past it, the drainer is what pulls it back down
            ssd_capacity = 4 * dram_capacity if self._ssd_path else 0
        self.ssd_capacity = ssd_capacity

    # ------------------------------------------------------- SSD log records
    @staticmethod
    def record_overhead(key: str) -> int:
        """File bytes a record costs beyond its payload (header + key)."""
        return _REC_HDR.size + len(key.encode("utf-8"))

    def _read_handle(self):
        """Cached read handle (caller holds _lock). Reopening the log on
        every SSD-tier read was measurably dumb; the handle is dropped
        whenever the underlying file is replaced (compact/recover)."""
        if self._read_fh is None:
            self._read_fh = open(self._ssd_path, "rb")
        return self._read_fh

    def _append_handle(self):
        """Cached append handle (caller holds _lock)."""
        if self._append_fh is None:
            self._append_fh = open(self._ssd_path, "ab")
        return self._append_fh

    def _drop_handles(self):
        """Invalidate cached handles; caller holds _lock. Called whenever
        the log file is swapped out from under them (compact/recover)."""
        for fh in (self._read_fh, self._append_fh):
            if fh is not None:
                fh.close()
        self._read_fh = self._append_fh = None

    def _append_record(self, f, key: str, payload: bytes, gen: int, *,
                       clean: bool = False, tombstone: bool = False) -> int:
        """Append one self-describing record; returns the *payload* offset
        (what the index stores, so reads never re-parse headers). Caller
        holds _lock and owns the flush/fsync policy."""
        kb = key.encode("utf-8")
        flags = (_REC_CLEAN if clean else 0) | (_REC_TOMB if tombstone else 0)
        crc = zlib.crc32(
            _REC_HDR.pack(_REC_MAGIC, flags, gen, len(kb), len(payload), 0))
        crc = zlib.crc32(kb, crc)
        crc = zlib.crc32(payload, crc) & 0xFFFFFFFF
        f.write(_REC_HDR.pack(_REC_MAGIC, flags, gen, len(kb),
                              len(payload), crc))
        f.write(kb)
        off = f.tell()
        f.write(payload)
        return off

    def _tombstone(self, key: str, gen: int):
        """Append + flush a tombstone record (caller holds _lock). NOT
        fsynced here: an fsync per evicted key serializes the drain engine
        on disk flushes, and every later fsync of the append handle (spill
        batch, compact, ``sync()``) covers all tombstones before it in the
        stream. Call sites where resurrection would serve STALE bytes (the
        write-through bypass evict, file truncate) must follow the batch
        with ``sync()``; a drain-epoch evict may skip it — the PFS copy is
        byte-identical, so a replay resurrecting the record is harmless."""
        f = self._append_handle()
        self._append_record(f, key, b"", gen, tombstone=True)
        f.flush()
        self._unsynced = True

    def sync(self):
        """Make every appended tombstone durable (coalesced fsync). No-op
        when nothing is pending."""
        with self._lock:
            if self._unsynced and self._ssd_path:
                f = self._append_handle()
                f.flush()
                t0 = self._clock()
                with telemetry.child_span("store.fsync", self.name,
                                          caller="sync"):
                    os.fsync(f.fileno())
                self._m_fsync.observe(self._clock() - t0, label="sync")
            self._unsynced = False

    def recover(self):
        """Rebuild the in-memory state from an existing SSD log (ISSUE 8).

        Scans records front to back, keeping the highest generation seen per
        key (compact preserves gens but reorders records, so file order is
        NOT gen order); a tombstone at the winning gen deletes the key. The
        scan stops at the first bad magic, impossible length, or CRC
        mismatch — everything from there is a torn tail from a mid-append
        crash and is truncated, restoring the append-only invariant. The
        index, ``_ssd_bytes`` and the generation counter are rebuilt;
        ``recovered_keys`` lists the live keys for manifest rebuild."""
        with self._lock:
            size = os.path.getsize(self._ssd_path)
            live: Dict[str, Tuple[int, int, int, bool, bool]] = {}
            pos = 0
            max_gen = 0
            with open(self._ssd_path, "rb") as f:
                while pos + _REC_HDR.size <= size:
                    f.seek(pos)
                    magic, flags, gen, klen, plen, crc = _REC_HDR.unpack(
                        f.read(_REC_HDR.size))
                    end = pos + _REC_HDR.size + klen + plen
                    if magic != _REC_MAGIC or end > size:
                        break
                    body = f.read(klen + plen)
                    want = zlib.crc32(_REC_HDR.pack(
                        _REC_MAGIC, flags, gen, klen, plen, 0))
                    want = zlib.crc32(body, want) & 0xFFFFFFFF
                    if want != crc:
                        self._m_crc.inc(label=self.name)
                        break
                    key = body[:klen].decode("utf-8", errors="replace")
                    max_gen = max(max_gen, gen)
                    cur = live.get(key)
                    if cur is None or gen >= cur[0]:
                        live[key] = (gen, pos + _REC_HDR.size + klen, plen,
                                     bool(flags & _REC_CLEAN),
                                     bool(flags & _REC_TOMB))
                    pos = end
            if pos < size:                      # torn tail: truncate it away
                telemetry.record("store", "torn_tail", store=self.name,
                                 truncated_at=pos, size=size)
                with open(self._ssd_path, "r+b") as f:
                    f.truncate(pos)
                    f.flush()
                    os.fsync(f.fileno())
            self._drop_handles()
            self.recovered_keys = []
            for key, (gen, off, plen, clean, dead) in sorted(
                    live.items(), key=lambda kv: kv[1][1]):
                if dead:
                    continue
                self._index[key] = _Loc("ssd", 0, off, plen, gen, clean)
                self._ssd_bytes += plen
                self.recovered_keys.append(key)
            self._gen = max(self._gen, max_gen)

    # ------------------------------------------------------------------ info
    @property
    def dram_used(self) -> int:
        with self._lock:
            return self._dram_bytes

    @property
    def ssd_used(self) -> int:
        with self._lock:
            return self._ssd_bytes

    def dram_free(self) -> int:
        with self._lock:
            return max(0, self.dram_capacity - self._dram_bytes)

    def occupancy(self) -> Dict[str, float]:
        """Watermark input: used bytes over total (DRAM + SSD) capacity.
        The fraction can exceed 1.0 — the SSD log is soft-capped and keeps
        absorbing; that is exactly the pressure signal the drainer acts on."""
        with self._lock:
            cap = self.dram_capacity + self.ssd_capacity
            used = self._dram_bytes + self._ssd_bytes
            return {"dram_used": self._dram_bytes,
                    "dram_capacity": self.dram_capacity,
                    "ssd_used": self._ssd_bytes,
                    "ssd_capacity": self.ssd_capacity,
                    "used": used, "capacity": cap,
                    "fraction": used / cap if cap else 0.0}

    def keys(self) -> List[str]:
        with self._lock:
            return [k for k, loc in self._index.items() if loc.tier != "pfs"]

    def __contains__(self, key: str) -> bool:
        with self._lock:
            loc = self._index.get(key)
            return loc is not None and loc.tier != "pfs"

    def tier_of(self, key: str) -> Optional[str]:
        """Residency of a key: "dram" | "ssd" | "pfs" (evicted) | None."""
        with self._lock:
            loc = self._index.get(key)
            return loc.tier if loc is not None else None

    def gen_of(self, key: str) -> Optional[int]:
        with self._lock:
            loc = self._index.get(key)
            return loc.gen if loc is not None else None

    def was_evicted(self, key: str) -> bool:
        return self.tier_of(key) == "pfs"

    def is_clean(self, key: str) -> bool:
        """True when the key's bytes were staged in from a durable PFS copy
        (and not rewritten since): evicting them loses nothing."""
        with self._lock:
            loc = self._index.get(key)
            return loc is not None and loc.tier != "pfs" and loc.clean

    # ----------------------------------------------------------------- write
    def put(self, key: str, value: bytes, *, clean: bool = False) -> str:
        """Append to the DRAM log; spill oldest segments to SSD if needed.
        Returns the tier the value landed in. ``clean`` marks the bytes as
        having a durable PFS copy already (stage-in re-ingest) — a plain
        rewrite of the same key clears the flag."""
        with self._lock:
            if key in self._index:
                self.delete(key)
            self._gen += 1
            seg = self._segments[self._open_seg]
            loc = _Loc("dram", self._open_seg, len(seg), len(value),
                       self._gen, clean)
            seg += value
            self._index[key] = loc
            self._dram_bytes += len(value)
            self._seg_touched[self._open_seg] = self._clock()
            if len(seg) >= self.segment_bytes:
                self._roll_segment()
            spilled = self._maybe_spill()
            return "ssd" if spilled and self._index[key].tier == "ssd" \
                else "dram"

    def _roll_segment(self):
        self._segments[self._next_seg] = bytearray()
        self._open_seg = self._next_seg
        self._seg_touched[self._open_seg] = self._clock()
        self._next_seg += 1

    def _maybe_spill(self) -> bool:
        """Spill closed segments (oldest first) while over DRAM capacity.

        Each live key becomes one self-describing record (dead bytes within
        the segment are dropped at the door — they'd only be compacted away
        later anyway). Durability before visibility: the batch is fsynced
        BEFORE the index publishes any entry as tier "ssd", so the index
        never trusts bytes a crash could lose."""
        if self._dram_bytes <= self.dram_capacity or not self._ssd_path:
            return False
        t0 = self._clock()
        # spill hysteresis: once over capacity, keep going down to a LOW
        # watermark so the batch's single fsync covers several segments —
        # an fsync per sealed segment serializes the ingest path on the
        # disk's flush latency and was measured 5x slower under drain
        target = max(0, self.dram_capacity
                     - max(self.dram_capacity // 4, self.segment_bytes))
        # if the open segment alone holds the overflow, roll it so it can
        # spill too (log-structured: only sealed segments move)
        if len(self._segments) == 1 and self._segments[self._open_seg]:
            self._roll_segment()
        pending: Dict[str, _Loc] = {}
        f = self._append_handle()
        for seg_id in sorted(self._segments):
            if self._dram_bytes <= target:
                break
            if seg_id == self._open_seg:
                continue
            data = self._segments.pop(seg_id)
            self._seg_touched.pop(seg_id, None)
            for k, loc in self._index.items():
                if loc.tier == "dram" and loc.segment == seg_id:
                    payload = bytes(data[loc.offset:loc.offset + loc.length])
                    off = self._append_record(f, k, payload, loc.gen,
                                              clean=loc.clean)
                    pending[k] = _Loc("ssd", 0, off, loc.length,
                                      loc.gen, loc.clean)
                    self._ssd_bytes += loc.length
            self._dram_bytes -= len(data)
        if not pending:
            return False
        f.flush()
        t1 = self._clock()
        with telemetry.child_span("store.fsync", self.name, caller="spill"):
            os.fsync(f.fileno())
        now = self._clock()
        self._m_fsync.observe(now - t1, label="spill")
        self._m_spill.observe(now - t0)
        self._unsynced = False    # the fsync covered any pending tombstones
        self._index.update(pending)
        return True

    # ------------------------------------------------------------------ read
    def get(self, key: str) -> Optional[bytes]:
        with self._lock:
            loc = self._index.get(key)
            if loc is None or loc.tier == "pfs":
                return None
            if loc.tier == "dram":
                seg = self._segments[loc.segment]
                return bytes(seg[loc.offset:loc.offset + loc.length])
            f = self._read_handle()
            f.seek(loc.offset)
            return f.read(loc.length)

    def delete(self, key: str):
        """Log-structured delete: drop the index entry (tombstones too);
        dead bytes are reclaimed by compact(). Deleting an SSD-resident key
        appends a tombstone record — durable at the next fsynced append or
        ``sync()`` — so a post-crash replay does not resurrect it (ISSUE
        8)."""
        with self._lock:
            loc = self._index.pop(key, None)
            if loc is not None and loc.tier == "ssd" and self._ssd_path:
                self._gen += 1
                self._tombstone(key, self._gen)

    def evict(self, key: str) -> int:
        """Tombstone a durably-flushed key: the index remembers it moved to
        the "pfs" tier (reads miss, residency is reportable), and the dead
        bytes are reclaimed by compact(). Idempotent — evicting a missing or
        already-evicted key frees 0, so a replayed drain_evict can never
        double-free accounting. An SSD-resident key also gets a tombstone
        record in the log: its PFS copy is the durable truth now, and a
        replay must not resurrect the buffered bytes (which may be older
        than the PFS copy on the write-through bypass path — those call
        sites follow the evict batch with ``sync()``)."""
        with self._lock:
            loc = self._index.get(key)
            if loc is None or loc.tier == "pfs":
                return 0
            if loc.tier == "ssd" and self._ssd_path:
                self._gen += 1
                self._tombstone(key, self._gen)
            self._index[key] = _Loc("pfs", -1, 0, loc.length, loc.gen)
            return loc.length

    def cold_keys(self, min_idle_s: float = 0.0,
                  now: Optional[float] = None, *,
                  clean: Optional[bool] = None) -> List[Tuple[str, int]]:
        """Drain candidates in age order: SSD-resident keys first (they
        spilled earliest, i.e. are the coldest), then keys of sealed DRAM
        segments oldest-segment-first. The open segment never drains, and a
        DRAM segment appended to within ``min_idle_s`` is considered warm.
        ``clean`` filters by the clean flag (True: only staged/re-ingested
        keys — the free-eviction candidates; False: only dirty keys — the
        ones that need a drain epoch; None: both). Returns [(key, length)]."""
        now = self._clock() if now is None else now
        with self._lock:
            ssd = sorted((loc.offset, k, loc.length)
                         for k, loc in self._index.items()
                         if loc.tier == "ssd"
                         and (clean is None or loc.clean == clean))
            dram = sorted(
                (loc.segment, loc.offset, k, loc.length)
                for k, loc in self._index.items()
                if loc.tier == "dram" and loc.segment != self._open_seg
                and (clean is None or loc.clean == clean)
                and now - self._seg_touched.get(loc.segment, 0.0)
                >= min_idle_s)
            return [(k, ln) for _, k, ln in ssd] \
                + [(k, ln) for _, _, k, ln in dram]

    def items_bytes(self) -> Dict[str, int]:
        with self._lock:
            return {k: loc.length for k, loc in self._index.items()
                    if loc.tier != "pfs"}

    def compact(self):
        """Reclaim dead bytes on BOTH tiers: drop fully-dead DRAM segments,
        and rewrite the SSD log keeping only live entries (one sequential
        copy, then an atomic replace) so deleted/evicted SSD bytes are
        actually returned — without this the drain engine would tombstone
        forever while the SSD file only ever grew."""
        with self._lock:
            live = {loc.segment for loc in self._index.values()
                    if loc.tier == "dram"}
            for seg_id in list(self._segments):
                if seg_id != self._open_seg and seg_id not in live:
                    self._dram_bytes -= len(self._segments[seg_id])
                    del self._segments[seg_id]
                    self._seg_touched.pop(seg_id, None)
            if not self._ssd_path:
                return
            ssd = sorted((loc.offset, k) for k, loc in self._index.items()
                         if loc.tier == "ssd")
            live_bytes = sum(self._index[k].length for _, k in ssd)
            if live_bytes >= self._ssd_bytes:
                self.sync()       # nothing dead; harden pending tombstones
                return
            t0 = self._clock()
            tmp = self._ssd_path + ".compact"
            new_locs: Dict[str, _Loc] = {}
            src = self._read_handle()
            with open(tmp, "wb") as dst:
                for _, k in ssd:
                    loc = self._index[k]
                    src.seek(loc.offset)
                    payload = src.read(loc.length)
                    off = self._append_record(dst, k, payload, loc.gen,
                                              clean=loc.clean)
                    new_locs[k] = _Loc("ssd", 0, off, loc.length,
                                       loc.gen, loc.clean)
                # fsync before the atomic replace publishes the rewrite; the
                # old log stays fully valid (live records + dead bytes)
                # until the rename, so a crash anywhere here replays cleanly
                dst.flush()
                t1 = self._clock()
                with telemetry.child_span("store.fsync", self.name,
                                          caller="compact"):
                    os.fsync(dst.fileno())
                self._m_fsync.observe(self._clock() - t1, label="compact")
            self._drop_handles()
            os.replace(tmp, self._ssd_path)
            # pending tombstones went out with the old file: a removed key
            # simply has no record in the new log, which replays the same
            self._unsynced = False
            self._index.update(new_locs)
            self._ssd_bytes = live_bytes
            self._m_compact.observe(self._clock() - t0)
