"""Hybrid-storage log-structured store (paper §V: DRAM + SSD spill).

Writes append to an in-memory segment log (DRAM tier); when DRAM capacity is
exceeded, *whole segments* spill to an SSD-tier file with a single sequential
append — log-structuring is exactly what made bbIORSSD (198.8 MB/s) match
SSDSeq (206 MB/s) in the paper's Fig 6 while direct semi-random writes got
166.7 MB/s. An index maps key -> (tier, segment/file, offset, length).
"""
from __future__ import annotations

import os
import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple


@dataclass
class _Loc:
    tier: str          # "dram" | "ssd"
    segment: int       # dram segment id or ssd file offset base id
    offset: int
    length: int


class LogStore:
    SEGMENT_BYTES = 4 << 20

    def __init__(self, dram_capacity: int, ssd_dir: Optional[str] = None,
                 name: str = "srv"):
        self.dram_capacity = dram_capacity
        self.ssd_dir = ssd_dir
        self.name = name
        self._segments: Dict[int, bytearray] = {}
        self._open_seg = 0
        self._segments[0] = bytearray()
        self._index: Dict[str, _Loc] = {}
        self._dram_bytes = 0
        self._ssd_bytes = 0
        self._next_seg = 1
        self._lock = threading.RLock()
        self._ssd_path = None
        if ssd_dir:
            os.makedirs(ssd_dir, exist_ok=True)
            self._ssd_path = os.path.join(ssd_dir, f"{name}.log")
            open(self._ssd_path, "wb").close()

    # ------------------------------------------------------------------ info
    @property
    def dram_used(self) -> int:
        with self._lock:
            return self._dram_bytes

    @property
    def ssd_used(self) -> int:
        with self._lock:
            return self._ssd_bytes

    def dram_free(self) -> int:
        with self._lock:
            return max(0, self.dram_capacity - self._dram_bytes)

    def keys(self) -> List[str]:
        with self._lock:
            return list(self._index)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._index

    # ----------------------------------------------------------------- write
    def put(self, key: str, value: bytes) -> str:
        """Append to the DRAM log; spill oldest segments to SSD if needed.
        Returns the tier the value landed in."""
        with self._lock:
            if key in self._index:
                self.delete(key)
            seg = self._segments[self._open_seg]
            loc = _Loc("dram", self._open_seg, len(seg), len(value))
            seg += value
            self._index[key] = loc
            self._dram_bytes += len(value)
            if len(seg) >= self.SEGMENT_BYTES:
                self._segments[self._next_seg] = bytearray()
                self._open_seg = self._next_seg
                self._next_seg += 1
            spilled = self._maybe_spill()
            return "ssd" if spilled and self._index[key].tier == "ssd" \
                else "dram"

    def _maybe_spill(self) -> bool:
        """Spill closed segments (oldest first) while over DRAM capacity."""
        if self._dram_bytes <= self.dram_capacity or not self._ssd_path:
            return False
        # if the open segment alone holds the overflow, roll it so it can
        # spill too (log-structured: only sealed segments move)
        if len(self._segments) == 1 and self._segments[self._open_seg]:
            self._segments[self._next_seg] = bytearray()
            self._open_seg = self._next_seg
            self._next_seg += 1
        spilled = False
        with open(self._ssd_path, "ab") as f:
            for seg_id in sorted(self._segments):
                if self._dram_bytes <= self.dram_capacity:
                    break
                if seg_id == self._open_seg:
                    continue
                data = bytes(self._segments.pop(seg_id))
                base = f.tell()
                f.write(data)                    # sequential append
                for k, loc in self._index.items():
                    if loc.tier == "dram" and loc.segment == seg_id:
                        self._index[k] = _Loc("ssd", 0, base + loc.offset,
                                              loc.length)
                self._dram_bytes -= len(data)
                self._ssd_bytes += len(data)
                spilled = True
        return spilled

    # ------------------------------------------------------------------ read
    def get(self, key: str) -> Optional[bytes]:
        with self._lock:
            loc = self._index.get(key)
            if loc is None:
                return None
            if loc.tier == "dram":
                seg = self._segments[loc.segment]
                return bytes(seg[loc.offset:loc.offset + loc.length])
            with open(self._ssd_path, "rb") as f:
                f.seek(loc.offset)
                return f.read(loc.length)

    def delete(self, key: str):
        """Log-structured delete: drop the index entry; dead bytes are
        reclaimed by compact() (DRAM) / background log GC (SSD)."""
        with self._lock:
            self._index.pop(key, None)

    def items_bytes(self) -> Dict[str, int]:
        with self._lock:
            return {k: loc.length for k, loc in self._index.items()}

    def compact(self):
        """Drop fully-dead DRAM segments (cheap; SSD log compaction would be
        a background task on a real deployment)."""
        with self._lock:
            live = {loc.segment for loc in self._index.values()
                    if loc.tier == "dram"}
            for seg_id in list(self._segments):
                if seg_id != self._open_seg and seg_id not in live:
                    self._dram_bytes -= len(self._segments[seg_id])
                    del self._segments[seg_id]
