# The paper's primary contribution: a burst buffer system with consistent-
# hashing placement (Ketama/ISO), a Chord-style server ring with
# stabilization, chain replication with pipelined ACKs, two-phase I/O
# flushing to the PFS, hybrid DRAM/SSD log-structured storage, and
# restart-from-buffer support. See DESIGN.md for the TPU/JAX adaptation.
from repro.core.system import BBConfig, BurstBufferSystem  # noqa: F401
from repro.core.client import BBClient                     # noqa: F401
from repro.core.drain import DrainConfig, DrainEngine      # noqa: F401
from repro.core.filesystem import (BBError, BBFile,        # noqa: F401
                                   BBFileSystem, BBFuture, BBWriteError)
from repro.core.server import BBServer                     # noqa: F401
from repro.core.manager import BBManager                   # noqa: F401
from repro.core.qos import (BandwidthArbiter,              # noqa: F401
                            CongestionWindows, LaneQueue, QoSConfig,
                            TrafficClassifier)
from repro.core.staging import ReadAhead, StageConfig      # noqa: F401
from repro.core.transport import Transport                 # noqa: F401
