"""Runtime lock-order tracking (the dynamic half of bbcheck rule 2).

Core modules create their locks through ``lock()``/``rlock()`` instead of
``threading.Lock()``/``threading.RLock()``. With tracking disabled (the
default) these return the plain threading primitives — zero overhead on
the hot paths. ``tests/conftest.py`` enables tracking for the whole test
suite and asserts zero recorded inversions at teardown, so every real
acquisition order the protocol exercises is checked on every CI run.

An inversion is recorded when lock B is acquired while A is held after the
opposite order (a path B -> ... -> A in the acquisition graph) was ever
observed — across all threads, whether or not the orders ever actually
deadlocked — and when two DISTINCT instances sharing one name are nested
(unordered same-class nesting: a self-deadlock candidate the name graph
cannot order). Names aggregate instances ("Endpoint._lock" is one node no
matter how many endpoints exist) because the protocol gives every instance
of a class the same role in the acquisition order; per-name edges are
exactly the invariant worth enforcing.
"""
from __future__ import annotations

import json
import sys
import threading
import traceback
from typing import Dict, List, Optional


def _call_site() -> str:
    f = sys._getframe(1)
    while f is not None and f.f_code.co_filename == __file__:
        f = f.f_back
    if f is None:                               # pragma: no cover
        return "?"
    return f"{f.f_code.co_filename.rsplit('/', 1)[-1]}:{f.f_lineno}"


class LockOrderTracker:
    """Global acquisition-order digraph + per-thread held-lock stacks."""

    def __init__(self):
        # outer name -> {inner name: "file:line" where first observed}
        self.edges: Dict[str, Dict[str, str]] = {}
        self.inversions: List[dict] = []
        self._mu = threading.Lock()
        self._tls = threading.local()

    # ------------------------------------------------------------- queries
    def _held(self) -> list:
        st = getattr(self._tls, "held", None)
        if st is None:
            st = []
            self._tls.held = st
        return st

    def held_names(self) -> List[str]:
        return [name for _lk, name, _n in self._held()]

    def _path_exists(self, src: str, dst: str) -> bool:
        seen = set()
        stack = [src]
        while stack:
            n = stack.pop()
            if n == dst:
                return True
            if n in seen:
                continue
            seen.add(n)
            stack.extend(self.edges.get(n, ()))
        return False

    # ------------------------------------------------------------- events
    def on_acquired(self, lk: "TrackedLock"):
        held = self._held()
        for ent in held:
            if ent[0] is lk:        # reentrant re-acquire: no new ordering
                ent[2] += 1
                return
        if held:
            site = _call_site()
            inner = lk.name
            with self._mu:
                for _obj, outer, _n in held:
                    if outer == inner:
                        self.inversions.append({
                            "kind": "same-name-nesting", "name": inner,
                            "site": site,
                            "thread": threading.current_thread().name,
                            "stack": traceback.format_stack()})
                        continue
                    known = self.edges.setdefault(outer, {})
                    if inner in known:
                        continue
                    if self._path_exists(inner, outer):
                        self.inversions.append({
                            "kind": "order-inversion",
                            "first": f"{inner} -> {outer} "
                                     f"(seen {self.edges[inner].get(outer)})",
                            "second": f"{outer} -> {inner}", "site": site,
                            "thread": threading.current_thread().name,
                            "stack": traceback.format_stack()})
                    known[inner] = site
        held.append([lk, lk.name, 1])

    # ------------------------------------------------------------ artifact
    def dump(self, path: str) -> str:
        """Write the acquisition digraph, every recorded inversion (with
        the stack captured when it was recorded), and a snapshot of each
        live thread's current stack to a JSON artifact — enough to
        reconstruct the interleaving post-mortem without re-running."""
        frames = sys._current_frames()
        threads = {}
        for t in threading.enumerate():
            f = frames.get(t.ident)
            threads[t.name] = traceback.format_stack(f) if f is not None \
                else []
        with self._mu:
            report = {"edges": self.edges,
                      "inversions": self.inversions,
                      "threads": threads}
            with open(path, "w") as fh:
                json.dump(report, fh, indent=2, sort_keys=True)
        return path

    def on_released(self, lk: "TrackedLock"):
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i][0] is lk:
                held[i][2] -= 1
                if held[i][2] == 0:
                    del held[i]
                return


class TrackedLock:
    """Lock/RLock wrapper feeding a LockOrderTracker."""

    __slots__ = ("name", "_lk", "_tr")

    def __init__(self, name: str, tracker: LockOrderTracker,
                 reentrant: bool = False):
        self.name = name
        self._tr = tracker
        self._lk = threading.RLock() if reentrant else threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._lk.acquire(blocking, timeout)
        if ok:
            self._tr.on_acquired(self)
        return ok

    def release(self):
        self._tr.on_released(self)
        self._lk.release()

    def __enter__(self) -> "TrackedLock":
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()


# ------------------------------------------------------------- module API
_tracker: Optional[LockOrderTracker] = None


def enable() -> LockOrderTracker:
    """Turn tracking on. Only locks CREATED after this call are tracked
    (the factories below capture the active tracker at construction)."""
    global _tracker
    if _tracker is None:
        _tracker = LockOrderTracker()
    return _tracker


def disable():
    global _tracker
    _tracker = None


def tracker() -> Optional[LockOrderTracker]:
    return _tracker


def lock(name: str):
    t = _tracker
    return threading.Lock() if t is None else TrackedLock(name, t)


def rlock(name: str):
    t = _tracker
    return threading.RLock() if t is None \
        else TrackedLock(name, t, reentrant=True)
