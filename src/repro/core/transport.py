"""Message transport between burst-buffer entities.

The paper uses CCI over Gemini/IB verbs; here entities (clients, servers,
manager) are threads in one process and the transport is a registry of
per-endpoint queues. All inter-entity interaction goes through ``send`` /
``request`` — entities never touch each other's state directly, so the
protocol logic is exactly what would run over a socket/RDMA transport on a
real deployment (swap Transport for a gRPC/CCI-backed one).

``drop()`` black-holes an endpoint (failure injection): messages to a dropped
endpoint vanish, requests to it time out — matching the paper's §IV-B2
timeout-based failure detection.
"""
from __future__ import annotations

import itertools
import queue
from dataclasses import dataclass
from typing import Any, Dict, Optional

from . import locktrack, telemetry


@dataclass
class Message:
    kind: str
    src: str
    dst: str
    payload: Any = None
    msg_id: int = 0
    reply_to: Optional[int] = None     # msg_id this replies to


class Endpoint:
    def __init__(self, name: str, transport: "Transport"):
        self.name = name
        self.transport = transport
        self.inbox: "queue.Queue[Message]" = queue.Queue()
        self._pending: Dict[int, "queue.Queue[Message]"] = {}
        self._lock = locktrack.lock("Endpoint._lock")

    def deliver(self, msg: Message):
        if msg.reply_to is not None:
            # pop, not get: one reply per request, and async requests have
            # no other cleanup point — leaving entries behind would leak one
            # per acked put on the hot ingest path
            with self._lock:
                waiter = self._pending.pop(msg.reply_to, None)
            if waiter is not None:
                waiter.put(msg)
                return
        self.inbox.put(msg)

    def recv(self, timeout: Optional[float] = None) -> Optional[Message]:
        try:
            return self.inbox.get(timeout=timeout)
        except queue.Empty:
            return None


class Transport:
    def __init__(self):
        self._endpoints: Dict[str, Endpoint] = {}
        self._dropped: set = set()
        self._ids = itertools.count(1)
        self._lock = locktrack.lock("Transport._lock")
        self.bytes_sent: Dict[str, int] = {}
        # per-kind message counter; the shared no-op when telemetry is off
        self._m_msgs = telemetry.counter("transport.msgs")
        # per-SOURCE counter (ISSUE 10): the health engine's silent-server
        # watchdog flags an endpoint whose send counter stops advancing
        # while its peers' advance — per-kind totals can't see that
        self._m_src = telemetry.counter("transport.src_msgs")

    def register(self, name: str) -> Endpoint:
        ep = Endpoint(name, self)
        with self._lock:
            self._endpoints[name] = ep
            self._dropped.discard(name)
        return ep

    def drop(self, name: str):
        """Fail an endpoint: all future traffic to it is black-holed."""
        with self._lock:
            self._dropped.add(name)

    def restore(self, name: str):
        with self._lock:
            self._dropped.discard(name)

    def alive(self, name: str) -> bool:
        with self._lock:
            return name in self._endpoints and name not in self._dropped

    def endpoints(self):
        with self._lock:
            return sorted(self._endpoints)

    def _size_of(self, payload) -> int:
        if isinstance(payload, (bytes, bytearray, memoryview)):
            return len(payload)
        if isinstance(payload, dict):
            return sum(self._size_of(v) for v in payload.values())
        if isinstance(payload, (list, tuple)):
            return sum(self._size_of(v) for v in payload)
        return 64   # control-message overhead estimate

    def send(self, src: str, dst: str, kind: str, payload: Any = None,
             reply_to: Optional[int] = None) -> int:
        # piggyback the sender's trace context (telemetry.TRACE_KEY) on
        # dict payloads so the receive-side dispatch loop can re-parent
        # its span under ours; replies route through here too
        payload = telemetry.trace_inject(payload)
        self._m_msgs.inc(label=kind)
        self._m_src.inc(label=src)
        msg_id = next(self._ids)
        with self._lock:
            ep = self._endpoints.get(dst)
            dead = dst in self._dropped or src in self._dropped
            self.bytes_sent[src] = self.bytes_sent.get(src, 0) \
                + self._size_of(payload)
        if ep is None or dead:
            return msg_id                          # black hole
        ep.deliver(Message(kind, src, dst, payload, msg_id, reply_to))
        return msg_id

    def request_async(self, src_ep: Endpoint, dst: str, kind: str,
                      payload: Any = None,
                      sink: Optional["queue.Queue[Message]"] = None) -> int:
        """Non-blocking RPC (paper Fig 4 pipelining): fire the request and
        return its msg_id immediately. The reply, when it arrives, is put on
        ``sink`` — one queue may serve many outstanding requests, which is
        exactly the client's ACK ledger. The caller owns deadline tracking;
        abandon an id with ``cancel_async`` so a late reply falls through to
        the regular inbox instead of a stale waiter."""
        payload = telemetry.trace_inject(payload)
        self._m_msgs.inc(label=kind)
        self._m_src.inc(label=src_ep.name)
        if sink is None:
            sink = queue.Queue()
        msg_id = next(self._ids)
        with src_ep._lock:
            src_ep._pending[msg_id] = sink
        with self._lock:
            ep = self._endpoints.get(dst)
            dead = dst in self._dropped or src_ep.name in self._dropped
            self.bytes_sent[src_ep.name] = \
                self.bytes_sent.get(src_ep.name, 0) + self._size_of(payload)
        if ep is not None and not dead:
            ep.deliver(Message(kind, src_ep.name, dst, payload, msg_id))
        return msg_id

    def cancel_async(self, src_ep: Endpoint, msg_id: int):
        """Stop routing the reply for an abandoned async request."""
        with src_ep._lock:
            src_ep._pending.pop(msg_id, None)

    def request(self, src_ep: Endpoint, dst: str, kind: str,
                payload: Any = None, timeout: float = 2.0) -> Optional[Message]:
        """Blocking RPC: send and wait for the reply (None on timeout)."""
        payload = telemetry.trace_inject(payload)
        self._m_msgs.inc(label=kind)
        self._m_src.inc(label=src_ep.name)
        waiter: "queue.Queue[Message]" = queue.Queue()
        msg_id = next(self._ids)
        with src_ep._lock:
            src_ep._pending[msg_id] = waiter
        with self._lock:
            ep = self._endpoints.get(dst)
            dead = dst in self._dropped or src_ep.name in self._dropped
            self.bytes_sent[src_ep.name] = \
                self.bytes_sent.get(src_ep.name, 0) + self._size_of(payload)
        if ep is not None and not dead:
            ep.deliver(Message(kind, src_ep.name, dst, payload, msg_id))
        try:
            return waiter.get(timeout=timeout)
        except queue.Empty:
            return None
        finally:
            with src_ep._lock:
                src_ep._pending.pop(msg_id, None)

    def reply(self, src: str, msg: Message, kind: str, payload: Any = None):
        self.send(src, msg.src, kind, payload, reply_to=msg.msg_id)
