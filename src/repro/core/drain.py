"""Autonomous drain policy: watermarks, burst detection, bandwidth tokens.

The paper's core promise is that a burst buffer "allows for gradual flushing
of data to back-end filesystems", yet an explicit, manager-triggered flush
cannot keep a staging area from filling under sustained ingest. Romanus et
al. (arXiv:1509.05492) call staging-area space management the central burst
buffer design challenge; Shi et al. (arXiv:1902.05746) show traffic-aware
drain scheduling is what keeps the SSD tier absorbing bursts. This module is
the pure per-server policy behind both observations:

  - watermark hysteresis over LogStore occupancy: crossing the high
    watermark starts draining, falling to the low watermark stops it;
  - a sliding-window burst detector: while ingest is hot, draining defers
    (absorption wins) — unless occupancy passes the panic watermark;
  - a token bucket capping drain bandwidth, so micro-epochs can never
    monopolize the store/transport against foreground ingest.

All inputs (occupancy, the clock) are passed in, so the policy unit-tests
without a server. The protocol driver — drain micro-epochs through the
two-phase planner, tombstone eviction, read fallthrough — lives in
server.py / manager.py.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

from repro.core.qos import RateWindow


@dataclass
class DrainConfig:
    enabled: bool = True
    high_watermark: float = 0.70    # occupancy fraction that starts draining
    low_watermark: float = 0.40     # occupancy fraction that stops draining
    panic_watermark: float = 0.90   # drain even while ingest is hot
    request_interval: float = 0.30  # min seconds between drain requests
    max_epoch_bytes: int = 32 << 20  # per-server contribution per micro-epoch
    bw_bytes_per_s: int = 256 << 20  # token-bucket drain bandwidth cap
    burst_window_s: float = 0.25    # ingest-rate sliding window
    hot_bytes_per_s: int = 96 << 20  # ingest rate that defers draining
    min_idle_s: float = 0.0         # segment idle age before it is "cold"
    epoch_timeout_s: float = 12.0   # manager aborts a stuck micro-epoch
    pressure_interval: float = 0.25  # cadence of pressure reports to manager


class DrainEngine:
    """Per-server drain policy state machine (pure; injected clock).

    ``bucket`` (ISSUE 5) replaces the engine's private token bucket with a
    shared one — the server passes its QoS ``BandwidthArbiter`` so drain
    micro-epochs and stage-in slices debit ONE background-bandwidth budget
    instead of each claiming their own against a foreground burst. The
    watermark/burst policy is unchanged either way."""

    def __init__(self, cfg: DrainConfig, now: Optional[float] = None,
                 bucket=None):
        self.cfg = cfg
        now = time.monotonic() if now is None else now
        self.draining = False           # watermark hysteresis state
        self._ingest = RateWindow(cfg.burst_window_s)
        # start with a full bucket: the first burst past the watermark must
        # be allowed to drain immediately, not wait out a refill period
        self._tokens = float(cfg.bw_bytes_per_s)
        self._token_t = now
        self._bucket = bucket
        self._last_request = -1e9
        self.stats = {"requests": 0, "deferred_hot": 0,
                      "granted_bytes": 0, "refunded_bytes": 0}

    # ---------------------------------------------------- burst detection
    def note_ingest(self, nbytes: int, now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        self._ingest.note(nbytes, now)

    def ingest_rate(self, now: Optional[float] = None) -> float:
        """Bytes/s of ingest over the sliding window."""
        now = time.monotonic() if now is None else now
        return self._ingest.rate(now)

    def hot(self, now: Optional[float] = None) -> bool:
        return self.ingest_rate(now) >= self.cfg.hot_bytes_per_s

    # ------------------------------------------------ watermark hysteresis
    def update(self, occupancy: float, now: Optional[float] = None) -> bool:
        """Advance the hysteresis state for one tick. Returns True when a
        drain micro-epoch should be REQUESTED now: the store is draining
        (between watermarks, entered from above high), ingest is not hot
        (unless occupancy passed the panic watermark — then space wins),
        and the request rate limit allows it."""
        now = time.monotonic() if now is None else now
        if occupancy >= self.cfg.high_watermark:
            self.draining = True
        elif occupancy <= self.cfg.low_watermark:
            self.draining = False
        if not self.draining:
            return False
        if self.hot(now) and occupancy < self.cfg.panic_watermark:
            self.stats["deferred_hot"] += 1
            return False
        if now - self._last_request < self.cfg.request_interval:
            return False
        return True

    def note_requested(self, now: Optional[float] = None):
        self._last_request = time.monotonic() if now is None else now
        self.stats["requests"] += 1

    def snapshot(self) -> dict:
        """Engine state for stats_query / the telemetry poll (ISSUE 9):
        the counters plus the hysteresis flag, as one plain dict."""
        return {**self.stats, "draining": self.draining}

    def note_scan(self, now: Optional[float] = None):
        """Rate-limit the next candidate scan without counting a request —
        a scan that found nothing drainable costs as much as one that did,
        so it must not repeat every server-loop tick."""
        self._last_request = time.monotonic() if now is None else now

    # ----------------------------------------------------- bandwidth tokens
    def _refill(self, now: float):
        rate = self.cfg.bw_bytes_per_s
        self._tokens = min(float(rate),
                           self._tokens + (now - self._token_t) * rate)
        self._token_t = now

    def peek(self, now: Optional[float] = None) -> int:
        """Currently available drain-bandwidth budget in bytes."""
        if self._bucket is not None:
            return self._bucket.peek(now)
        now = time.monotonic() if now is None else now
        self._refill(now)
        return max(0, int(self._tokens))

    def take(self, nbytes: int, now: Optional[float] = None) -> int:
        if self._bucket is not None:
            self.stats["granted_bytes"] += int(nbytes)
            return self._bucket.take(nbytes, now)
        return self._take_local(nbytes, now)

    def _take_local(self, nbytes: int, now: Optional[float] = None) -> int:
        """Debit ``nbytes`` of budget in full. The bucket may go NEGATIVE —
        a single cold segment can exceed what is left, and progress demands
        at least one segment per epoch — and peek() then reports 0 until
        the refill pays the debt back, which is what enforces the average
        bandwidth cap. Debiting exactly what was selected also keeps abort
        refunds symmetric: refund(bytes) returns precisely what take(bytes)
        charged, never fabricating tokens. The debt is floored at one
        bucket so a pathological selection cannot mortgage minutes."""
        now = time.monotonic() if now is None else now
        self._refill(now)
        self._tokens = max(self._tokens - int(nbytes),
                           -float(self.cfg.bw_bytes_per_s))
        self.stats["granted_bytes"] += int(nbytes)
        return int(nbytes)

    def refund(self, nbytes: int):
        """Return budget consumed by an aborted micro-epoch (the bytes were
        never actually drained, so they must not count against the cap)."""
        self.stats["refunded_bytes"] += nbytes
        if self._bucket is not None:
            self._bucket.refund(nbytes)
            return
        self._tokens = min(float(self.cfg.bw_bytes_per_s),
                           self._tokens + nbytes)
