"""BurstBufferSystem: wires manager + servers + clients over one transport.

This is the deployable composition root. On a real pod each server would be
one daemon per host and the transport a network fabric; here they are
threads, but all interaction is message-passing so the topology, protocols
and failure behaviour are identical.
"""
from __future__ import annotations

import os
import shutil
import tempfile
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core import telemetry
from repro.core.client import BBClient
from repro.core.drain import DrainConfig
from repro.core.filesystem import BBFileSystem
from repro.core.health import HealthConfig
from repro.core.manager import BBManager
from repro.core.qos import QoSConfig
from repro.core.server import BBServer
from repro.core.staging import StageConfig
from repro.core.transport import Transport


@dataclass
class BBConfig:
    num_servers: int = 4
    num_clients: int = 4
    replication: int = 2
    placement: str = "iso"              # iso | ketama | rendezvous
    dram_capacity: int = 64 << 20
    ssd_dir: Optional[str] = None       # None -> tmpdir
    ssd_capacity: Optional[int] = None  # None -> 4x dram (soft, for drain)
    segment_bytes: Optional[int] = None  # None -> LogStore.SEGMENT_BYTES
    pfs_dir: Optional[str] = None       # None -> tmpdir
    stabilize_interval: float = 0.25
    # write pipeline (paper Fig 4) / client-side write coalescing
    batch_bytes: int = 1 << 20          # flush a coalesced batch at this size
    coalesce_threshold: int = 64 << 10  # writes below this auto-coalesce
    chunk_bytes: int = 4 << 20          # BBFile striping unit
    # read path (ISSUE 4): one knob for every read-side RPC deadline, and
    # the thread fan-out width for parallel manifest/range fetches
    read_timeout: float = 1.0
    # control plane (ISSUE 5): one knob for every manager/control RPC
    # deadline (hellos, fs namespace ops, stage requests, failure probes)
    control_timeout: float = 1.0
    read_fanout: int = 4
    # cadence knobs (ISSUE 6): every run-loop poll / retry / scan interval
    # in core routes through here — bbcheck rule 5 rejects new literals
    startup_timeout: float = 10.0        # wait_ring bound at start()
    manager_poll_interval: float = 0.05  # manager run-loop recv timeout
    server_poll_interval: float = 0.02   # server run-loop idle recv timeout
    flush_poll_interval: float = 0.01    # manager wait_flush spin
    drain_serialize_poll: float = 0.005  # begin_flush wait-for-drain spin
    ack_poll_interval: float = 0.02      # client ACK-ledger event wait
    ack_scan_interval: float = 0.05      # client deadline-scan cadence
    client_drain_poll: float = 0.003     # client drain() spin
    connect_retry_interval: float = 0.05  # client connect() hello retry
    pump_join_timeout: float = 1.0       # client close() pump-thread join
    # autonomous drain engine (ISSUE 3): watermark-driven background flush
    drain: DrainConfig = field(default_factory=DrainConfig)
    # stage-in engine (ISSUE 4): PFS -> BB bulk re-ingest + read-ahead
    stage: StageConfig = field(default_factory=StageConfig)
    # QoS engine (ISSUE 5): traffic classification, priority lanes,
    # congestion windows, write-through bypass, unified background arbiter
    qos: QoSConfig = field(default_factory=QoSConfig)
    # health engine (ISSUE 10): SLO rules + stall watchdogs + critical-path
    # attribution, evaluated on the manager run loop every
    # ``health.interval_s`` (only when telemetry is enabled)
    health: HealthConfig = field(default_factory=HealthConfig)


class BurstBufferSystem:
    def __init__(self, cfg: BBConfig):
        self.cfg = cfg
        self.transport = Transport()
        self._tmp = tempfile.mkdtemp(prefix="bbsys_")
        self.ssd_dir = cfg.ssd_dir or os.path.join(self._tmp, "ssd")
        self.pfs_dir = cfg.pfs_dir or os.path.join(self._tmp, "pfs")
        os.makedirs(self.ssd_dir, exist_ok=True)
        os.makedirs(self.pfs_dir, exist_ok=True)

        self.manager = BBManager(self.transport, cfg.num_servers,
                                 drain_epoch_timeout=cfg.drain.epoch_timeout_s,
                                 poll_interval=cfg.manager_poll_interval,
                                 flush_poll_interval=cfg.flush_poll_interval,
                                 drain_serialize_poll=cfg.drain_serialize_poll,
                                 journal_path=os.path.join(
                                     self.ssd_dir, "manager.journal"),
                                 health_cfg=cfg.health)
        self.servers: Dict[str, BBServer] = {}
        for i in range(cfg.num_servers):
            name = f"server/{i}"
            self.servers[name] = self._make_server(name)
        self.clients: List[BBClient] = [
            BBClient(f"client/{i}", self.transport, client_index=i,
                     placement=cfg.placement, replication=cfg.replication,
                     read_timeout=cfg.read_timeout,
                     control_timeout=cfg.control_timeout,
                     read_fanout=cfg.read_fanout,
                     batch_bytes=cfg.batch_bytes,
                     coalesce_threshold=cfg.coalesce_threshold,
                     ack_poll_interval=cfg.ack_poll_interval,
                     ack_scan_interval=cfg.ack_scan_interval,
                     drain_poll_interval=cfg.client_drain_poll,
                     connect_retry_interval=cfg.connect_retry_interval,
                     pump_join_timeout=cfg.pump_join_timeout,
                     qos_cfg=cfg.qos)
            for i in range(cfg.num_clients)]
        self._fs: Optional[BBFileSystem] = None

    def _make_server(self, name: str) -> BBServer:
        """One construction path for initial, joining AND crash-restarted
        servers — a restarted server MUST come up with the same ssd_dir so
        its LogStore recovers the previous incarnation's log (ISSUE 8)."""
        cfg = self.cfg
        return BBServer(name, self.transport,
                        dram_capacity=cfg.dram_capacity,
                        ssd_dir=self.ssd_dir,
                        ssd_capacity=cfg.ssd_capacity,
                        segment_bytes=cfg.segment_bytes,
                        pfs_dir=self.pfs_dir,
                        replication=cfg.replication,
                        stabilize_interval=cfg.stabilize_interval,
                        poll_interval=cfg.server_poll_interval,
                        drain=cfg.drain, stage=cfg.stage, qos_cfg=cfg.qos)

    # ---------------------------------------------------------------- launch
    def start(self):
        self.manager.start()
        for s in self.servers.values():
            s.start()
            self.transport.send(s.tname, "manager", "register", {})
        assert self.manager.wait_ring(self.cfg.startup_timeout), \
            "ring init failed"
        for c in self.clients:
            c.connect()
        return self

    def stop(self):
        for c in self.clients:
            c.close()
        for s in self.servers.values():
            s.stop()
        self.manager.stop()
        shutil.rmtree(self._tmp, ignore_errors=True)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # --------------------------------------------------------------- actions
    def fs(self) -> BBFileSystem:
        """The file-session facade over this system's clients (one per
        application; handles from fs().open() stripe across all clients)."""
        if self._fs is None:
            self._fs = BBFileSystem(self.clients,
                                    chunk_bytes=self.cfg.chunk_bytes,
                                    pfs_dir=self.pfs_dir,
                                    read_fanout=self.cfg.read_fanout,
                                    stage=self.cfg.stage,
                                    qos_cfg=self.cfg.qos,
                                    control_timeout=self.cfg.control_timeout)
        return self._fs

    def flush(self, epoch: int, timeout: float = 30.0) -> bool:
        self.manager.begin_flush(epoch)
        return self.manager.wait_flush(epoch, timeout)

    def evict(self, prefix: str):
        self.manager.evict(prefix)

    def pressure(self) -> dict:
        """Cluster pressure view (autonomous drain engine): per-server
        occupancy reports + drain epoch/abort/evict counters."""
        return self.manager.pressure_report()

    def kill_server(self, name: str):
        """Failure injection: stop the thread and black-hole its traffic."""
        srv = self.servers[name]
        srv.stop()
        self.transport.drop(name)

    def join_server(self, pred: Optional[str] = None) -> str:
        i = len(self.servers)
        name = f"server/{i}"
        srv = self._make_server(name)
        self.servers[name] = srv
        srv.start()
        # the joining server knows the ring via the manager's ring_update;
        # seed its view first so it can serve immediately (paper Fig 3)
        srv.ring = self.manager.alive_ring() + [name]
        srv.alive = {s: True for s in srv.ring}
        self.transport.send(name, "manager", "join_request",
                            {"server": name, "pred": pred})
        return name

    def restart_server(self, name: str, pred: Optional[str] = None) -> BBServer:
        """Crash-recovery restart (ISSUE 8): bring a killed server back over
        its surviving SSD log. The new incarnation's LogStore replays the
        log (last-gen-wins, torn tail truncated), the server rebuilds its
        chunk manifests from the recovered keys, re-registers its transport
        endpoint (un-black-holing it), and rejoins the ring through the
        existing join_request path — the manager un-marks it dead and sends
        it the authoritative ring + lookup table."""
        srv = self._make_server(name)
        self.servers[name] = srv
        srv.start()
        srv.ring = self.manager.alive_ring() + [name]
        srv.alive = {s: True for s in srv.ring}
        self.transport.send(name, "manager", "join_request",
                            {"server": name, "pred": pred})
        return srv

    def server_stats(self) -> Dict[str, dict]:
        out = {}
        probe = self.clients[0] if self.clients else None
        for name in self.servers:
            if not self.transport.alive(name):
                continue
            r = self.transport.request(
                probe.ep, name, "stats_query", {},
                timeout=self.cfg.control_timeout) if probe else None
            if r is not None:
                out[name] = r.payload
        return out

    def scrape(self) -> dict:
        """Telemetry scrape (ISSUE 9): the full in-process registry snapshot
        plus a metrics_query round-trip to every live server. The registry
        is read directly (this process owns it), so the per-server probe
        asks only for the stats payload — ``{"instruments": True}`` would
        return the same shared registry once per server.

        Dead servers are skipped via ``transport.alive()`` (the scrape
        stays bounded by ``control_timeout`` per unreachable survivor) but
        never silently: ``expected`` lists the configured membership and
        ``missing`` whoever failed to answer, so bbstat/bbtop — and CI —
        can alert on a partial scrape (ISSUE 10).
        """
        out = {"registry": telemetry.snapshot(), "servers": {},
               "expected": sorted(self.servers), "missing": []}
        probe = self.clients[0] if self.clients else None
        if probe is None:
            out["missing"] = sorted(self.servers)
            return out
        for name in self.servers:
            r = self.transport.request(
                probe.ep, name, "metrics_query", {"instruments": False},
                timeout=self.cfg.control_timeout) \
                if self.transport.alive(name) else None
            if r is not None:
                out["servers"][name] = r.payload
            else:
                out["missing"].append(name)
        out["missing"].sort()
        return out

    def health(self) -> dict:
        """Latest health-engine report (ISSUE 10) via the ``health_query``
        protocol round-trip — exactly what a remote operator tool sees.
        Falls back to the manager's in-process report when there is no
        client endpoint to probe through (or the RPC times out)."""
        probe = self.clients[0] if self.clients else None
        if probe is not None:
            r = self.transport.request(
                probe.ep, "manager", "health_query", {},
                timeout=self.cfg.control_timeout)
            if r is not None and isinstance(r.payload, dict):
                report = dict(r.payload)
                report.pop(telemetry.TRACE_KEY, None)
                return report
        return self.manager.health_report()
