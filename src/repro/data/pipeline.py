"""Deterministic synthetic LM data pipeline.

Production-shaped: shardable across data-parallel hosts (each host generates
only its shard), background prefetch thread with bounded queue, and an
explicitly checkpointable iterator state (carried inside burst-buffer
checkpoints, so restore resumes the exact batch sequence — determinism is
what makes the failure-injection integration test bit-exact).

Batches are Zipf-ish token sequences with a shifted-copy labels field, plus
optional stub modality inputs (frame/patch embeddings) for audio/vlm archs.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticLMPipeline:
    def __init__(self, *, vocab_size: int, seq_len: int, global_batch: int,
                 shard_id: int = 0, num_shards: int = 1, seed: int = 1234,
                 enc_seq: int = 0, enc_dim: int = 0,
                 prefetch: int = 2):
        assert global_batch % num_shards == 0
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // num_shards
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.seed = seed
        self.enc_seq = enc_seq
        self.enc_dim = enc_dim
        self.step = 0
        self._queue: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._worker: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # --------------------------------------------------------- deterministic
    def _batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed, step, self.shard_id))
        # zipf-ish marginal over the vocab, clipped
        raw = rng.zipf(1.3, size=(self.local_batch, self.seq_len + 1))
        tokens = (raw % (self.vocab_size - 1)).astype(np.int32) + 1
        batch = {"inputs": tokens[:, :-1], "labels": tokens[:, 1:]}
        if self.enc_seq:
            batch["enc_input"] = rng.normal(
                0, 1, (self.local_batch, self.enc_seq, self.enc_dim)
            ).astype(np.float32)
        return batch

    # ------------------------------------------------------------- iterator
    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        if self._worker is None:
            batch = self._batch_at(self.step)
        else:
            batch = self._queue.get()
        self.step += 1
        return batch

    # ------------------------------------------------------------- prefetch
    def start_prefetch(self):
        if self._worker is not None:
            return self
        self._stop.clear()
        next_step = [self.step]

        def work():
            while not self._stop.is_set():
                b = self._batch_at(next_step[0])
                while not self._stop.is_set():
                    try:
                        self._queue.put(b, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                next_step[0] += 1

        self._worker = threading.Thread(target=work, daemon=True)
        self._worker.start()
        return self

    def stop_prefetch(self):
        self._stop.set()
        if self._worker is not None:
            self._worker.join(timeout=2)
            self._worker = None
        while not self._queue.empty():
            self._queue.get_nowait()

    # ----------------------------------------------------------- checkpoint
    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.seed,
                "shard_id": self.shard_id, "num_shards": self.num_shards}

    def load_state_dict(self, state: Dict[str, int]):
        assert state["seed"] == self.seed
        assert state["num_shards"] == self.num_shards
        was_prefetching = self._worker is not None
        if was_prefetching:
            self.stop_prefetch()
        self.step = int(state["step"])
        if was_prefetching:
            self.start_prefetch()
