"""Training step: loss, microbatch gradient accumulation, optimizer update.

The step consumes a global batch dict {"inputs": (B,S), "labels": (B,S),
optional "enc_input": (B,S_enc,E)} and runs ``accum_steps`` microbatches via
lax.scan, accumulating grads in ``cfg.grad_accum_dtype``. Optimizer is AdamW
or Adafactor per the arch config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.launch.sharding import constrain
from repro.models.common import padded_vocab
from repro.optim.adafactor import Adafactor
from repro.optim.adamw import AdamW
from repro.optim.grad import clip_by_global_norm
from repro.optim.schedule import warmup_cosine


class TrainState(NamedTuple):
    params: Any
    opt_state: Any


def make_optimizer(cfg, *, peak_lr=3e-4, warmup=200, total=10_000):
    sched = warmup_cosine(peak_lr, warmup, total)
    if cfg.optimizer == "adafactor":
        return Adafactor(lr=sched, momentum=0.9)
    state_dtype = ("bfloat16" if cfg.grad_accum_dtype == "bfloat16"
                   else "float32")
    return AdamW(lr=sched, state_dtype=state_dtype)


def init_train_state(cfg, model, optimizer, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt_state=optimizer.init(params))


def state_logical_axes(cfg, model, optimizer):
    """Logical-axis tree matching TrainState(params, opt_state): optimizer
    state mirrors param axes (factored Adafactor moments drop the factored
    dim's annotation)."""
    import jax
    from repro.models.common import is_desc
    from repro.optim.adafactor import Adafactor, AdafactorState
    from repro.optim.adamw import AdamW, AdamWState
    from repro.models import transformer

    descs = transformer.model_descs(cfg)
    p_axes = jax.tree.map(lambda d: d.axes, descs, is_leaf=is_desc)
    p_shapes = jax.tree.map(lambda d: d.shape, descs, is_leaf=is_desc)

    if isinstance(optimizer, AdamW):
        opt_axes = AdamWState(step=(), m=p_axes, v=p_axes)
    else:
        def vr_axes(a, s):
            return a[:-1] if len(s) >= 2 else a

        def vc_axes(a, s):
            return a[:-2] + (a[-1],) if len(s) >= 2 else (None,)

        def m_axes(a, s):
            return a if optimizer.momentum else (None,)

        zip_map = lambda f: jax.tree.map(
            f, p_axes, p_shapes,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                e is None or isinstance(e, str) for e in x))
        opt_axes = AdafactorState(step=(), vr=zip_map(vr_axes),
                                  vc=zip_map(vc_axes), m=zip_map(m_axes))
    return TrainState(params=p_axes, opt_state=opt_axes)


def cross_entropy(logits, labels, vocab_size: int):
    """logits: (B,S,Vp) any dtype; labels: (B,S) int32. f32 stable xent."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_train_step(cfg, model, optimizer, *, accum_steps: int = 1,
                    clip_norm: float = 1.0):
    vp = padded_vocab(cfg)

    def loss_fn(params, micro):
        if cfg.mtp_depth:
            from repro.models import transformer
            logits, mtp_logits = transformer.forward_with_mtp(
                cfg, params, micro["inputs"], micro.get("enc_input"))
            loss = cross_entropy(logits, micro["labels"], vp)
            # MTP target at position t is token t+2 = labels[t+1]
            mtp_loss = cross_entropy(mtp_logits, micro["labels"][:, 1:], vp)
            return loss + 0.3 * mtp_loss
        logits = model.forward(params, micro["inputs"],
                               micro.get("enc_input"))
        return cross_entropy(logits, micro["labels"], vp)

    def train_step(state: TrainState, batch):
        b = batch["inputs"].shape[0]
        mb = b // accum_steps
        adt = jnp.dtype(cfg.grad_accum_dtype)

        def micro_slices(x):
            x = x.reshape((accum_steps, mb) + x.shape[1:])
            # keep the *microbatch* dim data-parallel — without this, SPMD
            # may shard the accum dim instead and every weight matmul turns
            # into a partial-sum all-reduce of full activations
            return constrain(x, (None, "batch") + (None,) * (x.ndim - 2))

        micros = {k: micro_slices(v) for k, v in batch.items()}

        def accum_body(carry, micro):
            g_acc, l_acc = carry
            micro = {k: constrain(v, ("batch",) + (None,) * (v.ndim - 1))
                     for k, v in micro.items()}
            loss, grads = jax.value_and_grad(loss_fn)(state.params, micro)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(adt) / accum_steps, g_acc, grads)
            return (g_acc, l_acc + loss / accum_steps), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), state.params)
        if accum_steps > 1:
            (grads, loss), _ = jax.lax.scan(accum_body, (g0, 0.0), micros)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(
                state.params, {k: v[0] for k, v in micros.items()})

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt_state = optimizer.update(grads, state.opt_state,
                                             state.params)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return TrainState(params=params, opt_state=opt_state), metrics

    return train_step
