"""Serving steps: batched single-token decode + prefill, jit-friendly."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import padded_vocab


def make_decode_step(cfg, model):
    def decode_step(params, cache, tokens, pos):
        """tokens: (B,1) int32; pos: scalar int32 -> (logits (B,1,V), cache)."""
        logits, cache = model.decode_step(params, cache, tokens, pos)
        return logits, cache
    return decode_step


def make_prefill(cfg, model):
    def prefill(params, cache, tokens, enc_input=None):
        return model.prefill(params, cache, tokens, enc_input)
    return prefill


def greedy_token(cfg, logits):
    """Mask vocab padding, take argmax. logits: (B,1,Vp)."""
    v = cfg.vocab_size
    vp = padded_vocab(cfg)
    if vp != v:
        mask = jnp.arange(vp) < v
        logits = jnp.where(mask[None, None, :], logits, -jnp.inf)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
