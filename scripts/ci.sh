#!/usr/bin/env bash
# Tier-1 CI: the fast suite (slow markers excluded) under a hard timeout so
# a hung distributed test can never wedge CI. Override with CI_TIMEOUT=secs.
#
#   scripts/ci.sh                # tier-1 test suite
#   scripts/ci.sh --bench-smoke  # tiny ingest benchmark through the
#                                # BBFileSystem API (fails on zero
#                                # bandwidth), then a capped over-capacity
#                                # drain run that fails if sustained ingest
#                                # under the autonomous drainer drops below
#                                # the async put baseline floor or any
#                                # read-back byte differs, then a capped
#                                # cold-restart run (checkpoint fully
#                                # evicted to the PFS) that fails if the
#                                # stage-in + parallel fan-out restart is
#                                # not >= 3x the serial per-miss fallback
#                                # baseline or any read-back byte differs,
#                                # then a QoS contention run that fails if
#                                # checkpoint-lane p99 under a background
#                                # flood does not beat the FIFO baseline by
#                                # >= 2x, if the write-through bypass
#                                # raises occupancy above the drain
#                                # low-watermark, or if any stream reads
#                                # back inexact
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--bench-smoke" ]]; then
    shift
    timeout "${CI_TIMEOUT:-300}" python -m benchmarks.bench_ingress --smoke "$@"
    timeout "${CI_TIMEOUT:-300}" python -m benchmarks.bench_drain --smoke
    timeout "${CI_TIMEOUT:-300}" python -m benchmarks.bench_restart --smoke
    exec timeout "${CI_TIMEOUT:-300}" python -m benchmarks.bench_qos --smoke \
        --min-speedup=2
fi

exec timeout "${CI_TIMEOUT:-1800}" python -m pytest -q -m "not slow" "$@"
