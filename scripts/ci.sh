#!/usr/bin/env bash
# Tier-1 CI: the fast suite (slow markers excluded) under a hard timeout so
# a hung distributed test can never wedge CI. Override with CI_TIMEOUT=secs.
#
#   scripts/ci.sh                # tier-1 test suite
#   scripts/ci.sh --lint         # bbcheck static analysis over the core:
#                                # protocol completeness, lock-order graph,
#                                # no blocking under lock, clock injection,
#                                # no hardcoded interval literals, payload
#                                # schema agreement, epoch-table lifecycles,
#                                # thread-ownership races. Fails on any
#                                # violation not in the (shrinking-only)
#                                # committed allowlist, on docs/PROTOCOL.md
#                                # drifting from the code, or on the lint
#                                # pass blowing its 10s wall-clock budget.
#                                # Machine-readable report lands at
#                                # $BBCHECK_JSON (default
#                                # /tmp/bbcheck-report.json)
#   scripts/ci.sh --bench-smoke  # tiny ingest benchmark through the
#                                # BBFileSystem API (fails on zero
#                                # bandwidth), then a capped over-capacity
#                                # drain run that fails if sustained ingest
#                                # under the autonomous drainer drops below
#                                # the async put baseline floor or any
#                                # read-back byte differs, then a capped
#                                # cold-restart run (checkpoint fully
#                                # evicted to the PFS) that fails if the
#                                # stage-in + parallel fan-out restart is
#                                # not faster than the serial per-miss
#                                # fallback baseline (1.2x sanity floor —
#                                # the committed BENCH_restart baseline
#                                # holds the real line) or any read-back
#                                # byte differs, then a whole-cluster crash
#                                # recovery run (SSD-resident checkpoint,
#                                # cold restart over the surviving record
#                                # logs + manager journal) that fails if
#                                # any recovered byte differs or the
#                                # namespace does not come back,
#                                # with each bench's --json results held to
#                                # the committed benchmarks/baselines/
#                                # BENCH_*.json floors via benchmarks.compare,
#                                # then a QoS contention run that fails if
#                                # checkpoint-lane p99 under a background
#                                # flood does not beat the FIFO baseline
#                                # (1.2x sanity floor, committed baseline
#                                # holds the line), if the write-through bypass
#                                # raises occupancy above the drain
#                                # low-watermark, or if any stream reads
#                                # back inexact
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

# one collection point for every failure artifact (ISSUE 10): the lock-order
# inversion digraph, the flight-recorder rings, and the health-engine verdict
# dump all land under $BB_ARTIFACT_DIR so CI uploads a single folder
export BB_ARTIFACT_DIR="${BB_ARTIFACT_DIR:-/tmp/bb-artifacts}"
export BB_LOCK_ARTIFACT="${BB_LOCK_ARTIFACT:-$BB_ARTIFACT_DIR/bb-lock-inversions.json}"
export BB_FLIGHT_ARTIFACT="${BB_FLIGHT_ARTIFACT:-$BB_ARTIFACT_DIR/bb-flight.json}"
export BB_HEALTH_ARTIFACT="${BB_HEALTH_ARTIFACT:-$BB_ARTIFACT_DIR/bb-health.json}"
mkdir -p "$BB_ARTIFACT_DIR"

if [[ "${1:-}" == "--lint" ]]; then
    shift
    report="${BBCHECK_JSON:-/tmp/bbcheck-report.json}"
    SECONDS=0
    timeout "${CI_TIMEOUT:-120}" python -m tools.bbcheck \
        --json "$report" --check-protocol docs/PROTOCOL.md \
        --check-metrics docs/METRICS.md "$@"
    # the whole point of a pre-test lint is that it is effectively free:
    # all eight AST passes plus the registry render must stay under 10s
    if (( SECONDS >= 10 )); then
        echo "ci: bbcheck blew its 10s budget (took ${SECONDS}s)" >&2
        exit 1
    fi
    echo "ci: bbcheck report at $report (took ${SECONDS}s)"
    exit 0
fi

if [[ "${1:-}" == "--bench-smoke" ]]; then
    shift
    out="$(mktemp -d)"
    trap 'rm -rf "$out"' EXIT
    timeout "${CI_TIMEOUT:-300}" python -m benchmarks.bench_ingress --smoke \
        --json "$out/ingress.json" "$@"
    # each bench emits --json and is held to its committed BENCH_* baseline
    # (lenient 0.5x floor: catches collapses, tolerates machine variance)
    # NOTE: the drain baseline was re-pinned when spills became durable
    # (ISSUE 8): sustained ingest is now bounded by the disk's synchronous
    # flush bandwidth instead of the page-cache absorb rate
    timeout "${CI_TIMEOUT:-300}" python -m benchmarks.bench_drain --smoke \
        --json "$out/drain.json"
    python -m benchmarks.compare "$out/drain.json" \
        benchmarks/baselines/BENCH_drain.json
    # restart's measured speedup swings ~1.8-2.6x run-to-run on a noisy
    # shared machine, so the in-bench gate is only a sanity floor (staged
    # beats serial at all); the committed baseline holds the real line
    timeout "${CI_TIMEOUT:-300}" python -m benchmarks.bench_restart --smoke \
        --min-speedup=1.2 --json "$out/restart.json"
    python -m benchmarks.compare "$out/restart.json" \
        benchmarks/baselines/BENCH_restart.json
    # whole-cluster crash recovery (ISSUE 8): fails unless a cold restart
    # over the surviving SSD logs recovers every acked SSD-resident byte
    # byte-exact and the manager journal rebuilds the namespace
    timeout "${CI_TIMEOUT:-300}" python -m benchmarks.bench_recovery --smoke \
        --json "$out/recovery.json"
    python -m benchmarks.compare "$out/recovery.json" \
        benchmarks/baselines/BENCH_recovery.json
    # same story for the qos p99 ratio: observed 1.8-19x across runs on
    # this machine, so in-bench it only has to beat FIFO at all
    timeout "${CI_TIMEOUT:-300}" python -m benchmarks.bench_qos --smoke \
        --min-speedup=1.2 --json "$out/qos.json"
    python -m benchmarks.compare "$out/qos.json" \
        benchmarks/baselines/BENCH_qos.json
    # telemetry PR (ISSUE 9): every smoke record accretes — with the commit
    # hash — into benchmarks/history/BENCH_history.jsonl for trend-spotting
    python -m benchmarks.history "$out"/*.json
    # warn-only trend report (ISSUE 10): newest record vs trailing median
    # per headline metric — flags drifts the lenient compare floors miss,
    # but never fails the run (noisy shared machines swing these numbers)
    python -m benchmarks.history trend || true
    exit 0
fi

if ! timeout "${CI_TIMEOUT:-1800}" python -m pytest -q -m "not slow" "$@"; then
    echo "ci: FAILED — post-mortem artifacts (if written) under $BB_ARTIFACT_DIR:" >&2
    echo "ci:   lock-order inversions: $BB_LOCK_ARTIFACT" >&2
    echo "ci:   flight-recorder rings: $BB_FLIGHT_ARTIFACT" >&2
    echo "ci:   health-engine verdicts: $BB_HEALTH_ARTIFACT" >&2
    exit 1
fi
