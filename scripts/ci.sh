#!/usr/bin/env bash
# Tier-1 CI: the fast suite (slow markers excluded) under a hard timeout so
# a hung distributed test can never wedge CI. Override with CI_TIMEOUT=secs.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec timeout "${CI_TIMEOUT:-1800}" python -m pytest -q -m "not slow" "$@"
