"""Generate EXPERIMENTS.md tables from dry-run artifacts + bench CSV.

Usage: PYTHONPATH=src python scripts/gen_experiments.py
Reads results/dryrun (final), results/dryrun_v* (iteration history),
results/bench_output.csv if present; writes EXPERIMENTS.md by filling the
{{...}} slots in scripts/experiments_template.md.
"""
import json
import glob
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.report import (compare, load, md_multipod_delta,
                                   md_roofline_table, md_skip_table)


def fmt_compare(dir_a, dir_b, label_a, label_b, shape="train_4k"):
    rows = compare(dir_a, dir_b, shape=shape)
    lines = [f"| arch | temp GB {label_a} | temp GB {label_b} | "
             f"t_mem {label_a} | t_mem {label_b} | t_coll {label_a} | "
             f"t_coll {label_b} |", "|---|---|---|---|---|---|---|"]
    for arch, ta, tb, ma, mb, ca, cb in rows:
        lines.append(f"| {arch} | {ta:.1f} | {tb:.1f} | {ma:.1f} | {mb:.1f} "
                     f"| {ca:.1f} | {cb:.1f} |")
    return "\n".join(lines)


def dryrun_summary(rows):
    ok = [r for r in rows if r.get("status") == "ok"]
    sk = [r for r in rows if r.get("status") == "skipped"]
    err = [r for r in rows if r.get("status") == "error"]
    pods = [r for r in ok if r["mesh"].startswith("pod")]
    mps = [r for r in ok if "multipod" in r["mesh"]]
    return (f"**{len(ok)} compiled / {len(sk)} documented-skip / "
            f"{len(err)} error** across both meshes "
            f"({len(pods)} single-pod 16x16=256 chips, {len(mps)} "
            f"multi-pod 2x16x16=512 chips cells).")


def mem_fit_table(rows):
    ok = [r for r in rows if r.get("status") == "ok"
          and r["mesh"].startswith("pod") and r["shape"] == "train_4k"]
    lines = ["| arch | args GB/chip | temp GB/chip | fits 16 GB? |",
             "|---|---|---|---|"]
    for r in sorted(ok, key=lambda r: r["arch"]):
        a = r["memory_analysis"]["argument_size_in_bytes"] / 1e9
        t = r["memory_analysis"]["temp_size_in_bytes"] / 1e9
        fit = "yes" if t + 0 <= 16 else f"no (temp {t:.0f})"
        lines.append(f"| {r['arch']} | {a:.1f} | {t:.1f} | {fit} |")
    return "\n".join(lines)


def main():
    here = os.path.dirname(__file__)
    rows = load("results/dryrun")
    tpl = open(os.path.join(here, "experiments_template.md")).read()
    subs = {
        "{{DRYRUN_SUMMARY}}": dryrun_summary(rows),
        "{{ROOFLINE_TABLE}}": md_roofline_table(rows),
        "{{SKIP_TABLE}}": md_skip_table(rows),
        "{{MULTIPOD_TABLE}}": md_multipod_delta(
            [r for r in rows if r.get("shape") == "train_4k"]),
        "{{MEMFIT_TABLE}}": mem_fit_table(rows),
        "{{V2_V3_TABLE}}": fmt_compare(
            "results/dryrun_v2_trainsnapshot", "results/dryrun_v3",
            "pre", "post") if os.path.isdir("results/dryrun_v3") else "(n/a)",
    }
    for k, v in subs.items():
        tpl = tpl.replace(k, v)
    open("EXPERIMENTS.md", "w").write(tpl)
    print("wrote EXPERIMENTS.md", len(tpl), "bytes")


if __name__ == "__main__":
    main()
